import pytest

from repro.core.spec import (
    Neigh,
    NodeRef,
    PatternSpec,
    SEED_DST,
    SEED_SRC,
    Stage,
    StageT,
    TimeBound,
    Window,
)
from repro.core.patterns import build_pattern, PATTERN_NAMES


def test_all_library_patterns_validate():
    for name in PATTERN_NAMES:
        spec = build_pattern(name, 128)
        assert spec.emit_stage is not None


def test_duplicate_stage_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        PatternSpec(
            "bad",
            stages=(
                Stage("a", "count_window", operand=Neigh(SEED_SRC, "out")),
                Stage("a", "count_window", operand=Neigh(SEED_SRC, "in"), emit=True),
            ),
        )


def test_unbound_ref_rejected():
    with pytest.raises(ValueError, match="unbound"):
        PatternSpec(
            "bad",
            stages=(
                Stage(
                    "c",
                    "count_edges",
                    edge_src=NodeRef("ghost"),
                    edge_dst=SEED_SRC,
                    emit=True,
                ),
            ),
        )


def test_exactly_one_emit():
    with pytest.raises(ValueError, match="emit"):
        PatternSpec(
            "bad",
            stages=(
                Stage("a", "count_window", operand=Neigh(SEED_SRC, "out")),
            ),
        )


def test_anchor_on_undefined_stage_rejected():
    with pytest.raises(ValueError, match="anchor"):
        PatternSpec(
            "bad",
            stages=(
                Stage(
                    "c",
                    "count_window",
                    operand=Neigh(SEED_DST, "in"),
                    window=Window(TimeBound(StageT("nope"), 0), TimeBound(None, 1)),
                    emit=True,
                ),
            ),
        )


def test_bad_direction_rejected():
    with pytest.raises(ValueError, match="direction"):
        Neigh(SEED_SRC, "sideways")


def test_window_helpers():
    w = Window.after_seed(10)
    assert w.after.offset == 0 and w.until.offset == 10
    w = Window.before_seed(10)
    assert w.until.offset == -1
