import numpy as np
import jax.numpy as jnp
import pytest

from repro.ml.gbdt import GBDTClassifier, GBDTParams, _histograms
from repro.ml.metrics import (
    best_f1_threshold,
    confusion,
    f1_score,
    precision_recall_f1,
)
from repro.kernels import hist_update


def _toy(n=2000, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 6)).astype(np.float32)
    # nonlinear decision: XOR of two features + threshold on a third
    y = ((x[:, 0] * x[:, 1] > 0) & (x[:, 2] > -0.3)).astype(np.float32)
    return x, y


def test_gbdt_learns_nonlinear():
    x, y = _toy()
    clf = GBDTClassifier(GBDTParams(n_trees=30, max_depth=4, learning_rate=0.3))
    clf.fit(x[:1600], y[:1600])
    acc = float(np.mean(clf.predict(x[1600:]) == y[1600:]))
    assert acc > 0.9, acc


def test_gbdt_deterministic():
    x, y = _toy(800, 1)
    p1 = GBDTClassifier(GBDTParams(n_trees=8)).fit(x, y).predict_proba(x)
    p2 = GBDTClassifier(GBDTParams(n_trees=8)).fit(x, y).predict_proba(x)
    np.testing.assert_array_equal(p1, p2)


def test_gbdt_imbalanced_scale_pos_weight():
    rng = np.random.default_rng(2)
    n = 4000
    x = rng.normal(size=(n, 4)).astype(np.float32)
    y = np.zeros(n, dtype=np.float32)
    pos = rng.choice(n, size=60, replace=False)
    y[pos] = 1.0
    x[pos.astype(int), 0] += 2.5  # separable-ish signal
    clf = GBDTClassifier(GBDTParams(n_trees=25, max_depth=3))
    clf.fit(x, y)
    proba = clf.predict_proba(x)
    thr = best_f1_threshold(y, proba)
    f1 = f1_score(y, proba >= thr)
    assert f1 > 0.5, f1


def test_histogram_matches_pallas_kernel():
    """The jnp segment-sum histogram and the one-hot-matmul Pallas kernel
    are interchangeable backends."""
    rng = np.random.default_rng(3)
    n, f, n_bins, n_nodes = 512, 3, 16, 4
    xb = rng.integers(0, n_bins, (n, f)).astype(np.uint8)
    gh = rng.normal(size=(n, 2)).astype(np.float32)
    node = rng.integers(0, n_nodes, n).astype(np.int32)
    hist = np.asarray(
        _histograms(jnp.asarray(xb), jnp.asarray(gh), jnp.asarray(node), n_nodes, n_bins)
    )
    for feat in range(f):
        keys = node * n_bins + xb[:, feat].astype(np.int32)
        hk = np.asarray(
            hist_update(jnp.asarray(keys), jnp.asarray(gh), n_nodes * n_bins)
        ).reshape(n_nodes, n_bins, 2)
        np.testing.assert_allclose(hist[:, feat], hk, rtol=1e-4, atol=1e-4)


def test_metrics_confusion():
    y = np.array([1, 1, 0, 0, 1])
    p = np.array([1, 0, 1, 0, 1])
    c = confusion(y, p)
    assert (c["tp"], c["fp"], c["fn"], c["tn"]) == (2, 1, 1, 1)
    prec, rec, f1 = precision_recall_f1(y, p)
    assert abs(prec - 2 / 3) < 1e-9 and abs(rec - 2 / 3) < 1e-9
