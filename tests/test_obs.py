"""`repro.obs` contracts (ISSUE 9): span tracer, metrics registry,
flight recorder, and the instrumentation threaded through the executor,
the sharded dispatch pool, and the streaming service.

* Chrome trace-event export schema: ``traceEvents`` of ``"ph": "X"``
  complete events with microsecond ``ts``/``dur``, parent/span ids in
  ``args``, thread-id lanes — loadable by chrome://tracing / Perfetto;
* span nesting + counter-delta attribution (``stats=`` snapshots);
* disabled-tracer overhead: one branch + a shared no-op manager — the
  per-call cost is bounded in a microbench-style test;
* histogram quantiles match ``np.percentile`` exactly below the
  reservoir cap; count/sum stay exact past it;
* thread hammer: concurrent counter/histogram mutation is bit-exact;
* Prometheus text exposition shape;
* a 20-tick streaming run produces the per-stage tick span breakdown
  (tick -> ingest/plan/mine/score), ``TickReport.trace_misses`` decays
  to zero as the JIT cache warms (with a warning log on warm-tick
  misses), the flight recorder rings the reports, and a postmortem
  bundle dumps on demand;
* the real sharded path (8 virtual devices, subprocess) emits one
  ``dispatch:shard{k}`` span per shard with per-shard counter deltas
  while ``host_syncs`` stays 1.
"""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.obs import flight as obs_flight
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@pytest.fixture()
def tracer():
    """A private enabled tracer installed as the global one (restored
    after the test) — instrumented library code sees it."""
    prev = obs_trace.set_tracer(obs_trace.Tracer(enabled=True))
    try:
        yield obs_trace.get_tracer()
    finally:
        obs_trace.set_tracer(prev)


@pytest.fixture()
def registry():
    prev = obs_metrics.set_registry(obs_metrics.MetricsRegistry())
    try:
        yield obs_metrics.get_registry()
    finally:
        obs_metrics.set_registry(prev)


# ----------------------------------------------------------------------
# tracer
# ----------------------------------------------------------------------
def test_span_nesting_and_chrome_schema(tracer, tmp_path):
    with tracer.span("outer", label="root"):
        with tracer.span("inner:a"):
            pass
        with tracer.span("inner:b"):
            tracer.instant("marker", note="x")
    spans = tracer.spans()
    by_name = {ev["name"]: ev for ev in spans}
    assert set(by_name) == {"outer", "inner:a", "inner:b", "marker"}
    # children closed before the parent and link to it
    outer = by_name["outer"]
    for child in ("inner:a", "inner:b"):
        assert by_name[child]["parent"] == outer["id"]
    assert by_name["marker"]["parent"] == by_name["inner:b"]["id"]
    assert outer["parent"] is None
    assert all(ev["dur_ns"] >= 0 for ev in spans)

    path = tmp_path / "trace.json"
    out = tracer.export_chrome(str(path))
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(out))
    assert isinstance(loaded["traceEvents"], list)
    assert loaded["displayTimeUnit"] == "ms"
    complete = [e for e in loaded["traceEvents"] if e["ph"] == "X"]
    instants = [e for e in loaded["traceEvents"] if e["ph"] == "i"]
    assert len(complete) == 3 and len(instants) == 1
    for e in loaded["traceEvents"]:
        assert set(("name", "cat", "pid", "tid", "ts", "args")) <= set(e)
        assert isinstance(e["ts"], float)
        assert "span_id" in e["args"]
    # parent links survive into args, ts/dur are microseconds
    inner = next(e for e in complete if e["name"] == "inner:a")
    root = next(e for e in complete if e["name"] == "outer")
    assert inner["args"]["parent_span_id"] == root["args"]["span_id"]
    assert root["dur"] >= inner["dur"] >= 0
    assert root["ts"] <= inner["ts"]


def test_span_stats_delta_attribution(tracer):
    stats = {"kernel_calls": 3, "bytes_h2d": 100, "name": "not-numeric"}
    with tracer.span("work", stats=stats, strat="bulk"):
        stats["kernel_calls"] += 4
        stats["bytes_h2d"] += 256
    (ev,) = tracer.spans()
    assert ev["attrs"]["kernel_calls"] == 4
    assert ev["attrs"]["bytes_h2d"] == 256
    assert ev["attrs"]["strat"] == "bulk"
    assert "name" not in ev["attrs"]  # non-numeric keys are not diffed


def test_span_records_exception_and_unwinds_stack(tracer):
    with pytest.raises(ValueError):
        with tracer.span("boom"):
            raise ValueError("x")
    (ev,) = tracer.spans()
    assert ev["attrs"]["error"] == "ValueError"
    assert tracer.current_span_id() is None  # stack unwound


def test_disabled_tracer_is_noop_singleton_and_cheap():
    tr = obs_trace.Tracer(enabled=False)
    a = tr.span("x", stats={"k": 1}, attr=1)
    b = tr.span("y")
    assert a is b  # shared no-op: no allocation on the disabled path
    with a as sp:
        assert sp.span_id is None
        sp.set(ignored=True)
    assert tr.spans() == []
    assert tr.current_span_id() is None

    # microbench bound: the disabled call is one branch + a constant —
    # budget 5 us/call, ~50x slack over the measured cost, so the bound
    # holds on a loaded single-core CI runner
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("hot"):
            pass
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 5e-6, f"disabled span cost {per_call * 1e9:.0f}ns"


def test_tracer_capacity_drops_oldest(tracer):
    tracer.capacity = 10
    for i in range(25):
        with tracer.span(f"s{i}"):
            pass
    spans = tracer.spans()
    assert len(spans) == 10
    assert tracer.dropped == 15
    assert [ev["name"] for ev in spans] == [f"s{i}" for i in range(15, 25)]
    assert "dropped" in tracer.summary()


def test_tracer_thread_lanes(tracer):
    def worker(k):
        with tracer.span(f"w{k}"):
            time.sleep(0.001)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tracer.spans()
    assert len(spans) == 4
    assert all(ev["parent"] is None for ev in spans)  # per-thread stacks
    assert len({ev["tid"] for ev in spans}) == 4


# ----------------------------------------------------------------------
# metrics
# ----------------------------------------------------------------------
def test_histogram_quantiles_match_numpy(registry):
    rng = np.random.default_rng(7)
    vals = rng.exponential(scale=3.0, size=2000)
    h = registry.histogram("lat", help="latency")
    for v in vals:
        h.observe(float(v))
    # below the reservoir cap every observation is kept: quantiles are
    # np.percentile bit-for-bit
    for q in (0.5, 0.9, 0.99):
        assert h.quantile(q) == float(np.percentile(vals, q * 100.0))
    assert h.count == len(vals)
    assert h.sum == pytest.approx(vals.sum())


def test_histogram_reservoir_bounds_memory_keeps_exact_count(registry):
    h = registry.histogram("big", reservoir=64)
    for i in range(1000):
        h.observe(float(i))
    assert h.count == 1000
    assert h.sum == sum(range(1000))
    assert len(h._samples) == 64  # bounded
    q50 = h.quantile(0.5)
    assert 0.0 <= q50 <= 999.0


def test_registry_threaded_hammer_bit_exact(registry):
    c = registry.counter("hits")
    h = registry.histogram("obs")
    g = registry.gauge("hw")
    n_threads, per = 8, 5000

    def worker(k):
        for i in range(per):
            c.inc()
            h.observe(1.0)
            g.max_set(k * per + i)

    threads = [
        threading.Thread(target=worker, args=(k,)) for k in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * per  # no dropped increments
    assert h.count == n_threads * per
    assert h.sum == float(n_threads * per)
    assert g.value == n_threads * per - 1


def test_exposition_and_snapshot_shape(registry):
    registry.counter("reqs", help="requests").inc(3)
    registry.gauge("level").set(2)
    registry.counter(
        "beats", labels={"device": "cpu:0"}
    ).inc(5)
    h = registry.histogram("lat", help="latency seconds")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    text = registry.exposition()
    assert "# HELP reqs requests" in text
    assert "# TYPE reqs counter" in text
    assert "reqs 3" in text
    assert "# TYPE level gauge" in text
    assert 'beats{device="cpu:0"} 5' in text
    assert "# TYPE lat summary" in text
    assert 'lat{quantile="0.5"}' in text
    assert "lat_count 4" in text
    assert "lat_sum 10.0" in text

    snap = registry.snapshot()
    assert snap["reqs"] == 3
    assert snap['beats{device="cpu:0"}'] == 5
    assert snap["lat_count"] == 4
    assert snap['lat{quantile="0.5"}'] == 2.5
    json.dumps(snap)  # JSON-friendly end to end


def test_registry_kind_collision_raises(registry):
    registry.counter("x")
    with pytest.raises(TypeError):
        registry.gauge("x")


def test_observe_stats_counters_and_gauges(registry):
    obs_metrics.observe_stats(
        {"kernel_calls": 3, "jit_cache_entries": 5}, "ex", registry=registry
    )
    obs_metrics.observe_stats(
        {"kernel_calls": 2, "jit_cache_entries": 4}, "ex", registry=registry
    )
    snap = registry.snapshot()
    assert snap["ex_kernel_calls"] == 5  # counter: deltas sum
    assert snap["ex_jit_cache_entries"] == 5  # gauge: high-water mark


# ----------------------------------------------------------------------
# flight recorder
# ----------------------------------------------------------------------
def test_flight_recorder_ring_and_dump(tracer, tmp_path):
    fr = obs_flight.FlightRecorder(capacity=3)
    for i in range(5):
        with tracer.span("tick", tick=i) as sp:
            with tracer.span("tick:mine"):
                pass
        fr.record({"tick": i, "arr": np.int64(i)}, span_id=sp.span_id)
    assert len(fr) == 3  # ring bound
    assert fr.n_recorded == 5
    last = fr.last()
    assert last["report"]["tick"] == 4
    assert last["report"]["arr"] == 4  # numpy scalar -> plain int
    # the span tree of the tick rode along (tick + its mine child)
    names = sorted(s["name"] for s in last["spans"])
    assert names == ["tick", "tick:mine"]

    path = tmp_path / "post" / "bundle.jsonl"
    fr.dump(str(path), reason="test", failure={"type": "Boom"})
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    header, entries = lines[0], lines[1:]
    assert header["postmortem"] and header["reason"] == "test"
    assert header["failure"]["type"] == "Boom"
    assert header["ticks_in_ring"] == 3 and header["ticks_recorded"] == 5
    assert [e["report"]["tick"] for e in entries] == [2, 3, 4]  # oldest first


def test_flight_recorder_skips_spans_when_disabled():
    fr = obs_flight.FlightRecorder()
    prev = obs_trace.set_tracer(obs_trace.Tracer(enabled=False))
    try:
        fr.record({"tick": 1}, span_id=7)
    finally:
        obs_trace.set_tracer(prev)
    assert fr.last()["spans"] is None


# ----------------------------------------------------------------------
# streaming instrumentation (20 ticks, per-stage breakdown)
# ----------------------------------------------------------------------
def _feed(rng, n, lo):
    src = rng.integers(0, 40, n).astype(np.int32)
    dst = rng.integers(0, 40, n).astype(np.int32)
    t = (np.arange(n) + lo).astype(np.int64)
    amt = rng.random(n).astype(np.float32)
    return src, dst, t, amt


def test_streaming_20_ticks_trace_and_flight(tracer, registry, tmp_path, caplog):
    from repro.stream.service import DetectionService

    svc = DetectionService(
        ["fan_in", "cycle2"],
        window=128,
        thresholds={"fan_in": 2, "cycle2": 1},
    )
    rng = np.random.default_rng(3)
    reports = []
    with caplog.at_level("WARNING", logger="repro.stream"):
        for k in range(20):
            batch = svc.submit(*_feed(rng, 30, 30 * k))
            reports.append(batch.report)

    # every report joins its span tree and counts its fresh traces
    assert all(r.span_id is not None for r in reports)
    assert len({r.span_id for r in reports}) == 20
    assert reports[0].trace_misses > 0  # cold tick compiles
    assert reports[-1].trace_misses == 0  # warm cache replays
    # a warm tick that minted a trace logged the latency-smell warning
    warm_missed = [
        r for r in reports if r.path in ("local", "full") and r.trace_misses
    ]
    warned = [rec for rec in caplog.records if "fresh JIT trace" in rec.message]
    assert len(warned) == len(warm_missed)

    # per-stage breakdown: each tick span parents ingest/plan/mine, the
    # scored ticks parent a score span
    spans = tracer.spans()
    by_id = {ev["id"]: ev for ev in spans}
    ticks = [ev for ev in spans if ev["name"] == "tick"]
    assert len(ticks) == 20
    for r in reports:
        kids = {
            ev["name"] for ev in spans if ev["parent"] == r.span_id
        }
        assert {"tick:ingest", "tick:plan", "tick:mine"} <= kids
    assert any(ev["name"] == "tick:score" for ev in spans)
    # stage spans nest under the tick:mine stage, carrying counter deltas
    mines = [ev for ev in spans if ev["name"] == "tick:mine"]
    assert any(ev["attrs"].get("kernel_calls", 0) > 0 for ev in mines)
    launches = [ev for ev in spans if ev["name"] == "launch"]
    assert launches and all(
        by_id[ev["parent"]]["name"] in ("tick:mine", "tick:witness")
        or by_id[by_id[ev["parent"]]["parent"]]["name"]
        in ("tick:mine", "tick:witness")
        for ev in launches
        if ev["parent"] is not None
    )

    # chrome export round-trips and carries every tick lane
    out = tracer.export_chrome(str(tmp_path / "stream.json"))
    names = {e["name"] for e in out["traceEvents"]}
    assert {"tick", "tick:ingest", "tick:plan", "tick:mine"} <= names

    # the flight recorder rang every tick with its span tree
    assert len(svc.flight) == 20
    last = svc.flight.last()
    assert last["report"]["tick"] == 20
    assert {"tick", "tick:ingest"} <= {s["name"] for s in last["spans"]}
    dump = svc.flight.dump(str(tmp_path / "bundle.jsonl"))
    assert os.path.exists(dump)

    # tick latency histogram + executor counters landed in the registry
    snap = registry.snapshot()
    assert snap["repro_stream_tick_seconds_count"] == 20
    assert snap["repro_executor_kernel_calls"] > 0
    assert snap["repro_stream_trace_misses_total"] == sum(
        r.trace_misses for r in reports
    )


def test_streaming_tick_report_span_id_none_when_disabled(registry):
    from repro.stream.service import DetectionService

    svc = DetectionService(["fan_in"], window=64, thresholds={"fan_in": 2})
    rng = np.random.default_rng(5)
    batch = svc.submit(*_feed(rng, 20, 0))
    assert batch.report.span_id is None
    assert batch.report.trace_misses > 0  # counted even without tracing
    assert len(svc.flight) == 1
    assert svc.flight.last()["spans"] is None


def test_resilient_postmortem_bundle_on_exhausted_retries(tmp_path, registry):
    from repro.stream.chaos import FaultInjector, TransientFault
    from repro.stream.resilience import (
        ResilienceConfig,
        ResilientDetectionService,
    )

    chaos = FaultInjector()
    chaos.arm("mine", tick=2, times=-1)  # tick 2 fails every attempt
    svc = ResilientDetectionService(
        ["fan_in"],
        window=64,
        thresholds={"fan_in": 2},
        chaos=chaos,
        resilience=ResilienceConfig(
            postmortem_dir=str(tmp_path / "post"),
            max_retries=1,
            backoff_s=0.0,
        ),
    )
    rng = np.random.default_rng(9)
    svc.submit(*_feed(rng, 25, 0))  # tick 1 commits
    with pytest.raises(TransientFault):
        svc.submit(*_feed(rng, 25, 25))  # tick 2 exhausts retries
    bundles = list((tmp_path / "post").glob("postmortem_tick_*.jsonl"))
    assert len(bundles) == 1
    lines = [json.loads(l) for l in bundles[0].read_text().splitlines()]
    assert lines[0]["postmortem"] and lines[0]["reason"] == "tick_failed"
    assert lines[0]["failure"]["type"] == "TransientFault"
    # the ring preserved the COMMITTED tick leading up to the crash
    assert [e["report"]["tick"] for e in lines[1:]] == [1]
    snap = registry.snapshot()
    assert snap["repro_resilience_retries_total"] == 1


def test_triage_server_metrics_endpoint_and_audit_span_ids(
    tracer, registry, tmp_path
):
    from repro.launch.serve import TriageServer
    from repro.stream.service import DetectionService

    audit = tmp_path / "audit.jsonl"
    svc = DetectionService(["fan_in"], window=64, thresholds={"fan_in": 1})
    server = TriageServer(svc, audit_path=str(audit))
    rng = np.random.default_rng(11)
    for k in range(3):
        server.submit(*_feed(rng, 25, 25 * k))
    snap = server.metrics()
    assert snap["repro_triage_submit_seconds_count"] == 3
    assert "repro_triage_submit_seconds" in server.metrics("prometheus")
    with pytest.raises(ValueError):
        server.metrics("xml")
    server.close()
    lines = [json.loads(l) for l in audit.read_text().splitlines()]
    alerts = [l for l in lines if "eid" in l and not l.get("dedup")]
    assert alerts, "portfolio with threshold 1 must alert"
    # audit lines join the tick's span tree
    tick_span_ids = {ev["id"] for ev in tracer.spans() if ev["name"] == "tick"}
    assert all(l["span_id"] in tick_span_ids for l in alerts)
    # close() flushed the final metrics snapshot into the audit stream
    metric_lines = [l for l in lines if l.get("metrics")]
    assert len(metric_lines) == 1
    assert (
        metric_lines[0]["snapshot"]["repro_triage_submit_seconds_count"] == 3
    )


# ----------------------------------------------------------------------
# sharded instrumentation (real multi-device path, subprocess)
# ----------------------------------------------------------------------
_SHARDED_TRACE_SCRIPT = r"""
import json
import numpy as np
from repro import obs
obs.trace.enable()
from repro.api import MiningSession
from tests.conftest import random_temporal_graph

rng = np.random.default_rng(13)
g = random_temporal_graph(rng, n_nodes=18, n_edges=140, t_max=256)
session = MiningSession(g, window=96).register("fan_in", "cycle3")
res = session.mine(backend="sharded", n_parts=8)
out = obs.trace.get_tracer().export_chrome("%(path)s")
evs = out["traceEvents"]
disp = [e for e in evs if e["name"].startswith("dispatch:shard")]
print(json.dumps({
    "gather_mode": res.gather_mode,
    "host_syncs": int(res.stats["host_syncs"]),
    "dispatch_spans": sorted(e["name"] for e in disp),
    "shard_kernel_calls": sum(
        int(e["args"].get("kernel_calls", 0)) for e in disp
    ),
    "mine_kernel_calls": int(res.stats["kernel_calls"]),
    "gather_modes": sorted(
        e["args"].get("mode", "") for e in evs if e["name"] == "gather"
    ),
    "beat_metrics": sum(
        1
        for k in obs.metrics.get_registry().snapshot()
        if k.startswith("repro_shard_worker_beats")
    ),
}))
"""


def test_sharded_trace_multi_device_subprocess(tmp_path):
    """8 virtual devices: every shard dispatch emits its own span whose
    counter deltas sum to the mine totals, the collective gather emits
    one gather span, the trace is valid Chrome JSON, and instrumentation
    did not add a host sync."""
    trace_path = str(tmp_path / "mine.trace.json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_TRACE_SCRIPT % {"path": trace_path}],
        capture_output=True,
        text=True,
        env=env,
        cwd=root,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["gather_mode"] == "collective"
    assert got["host_syncs"] == 1  # tracing adds no syncs
    assert got["dispatch_spans"] == [f"dispatch:shard{k}" for k in range(8)]
    # per-shard span counter deltas reassemble the mine-level total
    assert got["shard_kernel_calls"] == got["mine_kernel_calls"]
    assert got["gather_modes"] == ["collective"]
    assert got["beat_metrics"] == 8  # one liveness gauge per device

    with open(trace_path) as f:
        trace = json.load(f)
    assert {e["name"] for e in trace["traceEvents"]} >= {
        "dispatch:shard0",
        "gather",
        "stage",
        "launch",
    }
