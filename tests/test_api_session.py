"""Portfolio MiningSession contracts: session == per-pattern loop ==
oracle (exactness), strictly fewer kernel invocations than the loop on
the "full" group (the fusion win), canonical-plan dedup, every backend,
and the deprecation shims."""
import numpy as np
import pytest

from repro.api import (
    MiningSession,
    canonical_key,
    featurize,
    mine_features,
    pattern,
    seed,
    var,
)
from repro.core.compiler import CompiledPattern
from repro.core.oracle import GFPReference
from repro.core.patterns import build_pattern, feature_pattern_set
from tests.conftest import random_temporal_graph

W = 96


@pytest.fixture(scope="module")
def dense_graph():
    rng = np.random.default_rng(11)
    return random_temporal_graph(rng, n_nodes=18, n_edges=140, t_max=256)


def test_full_group_fewer_kernel_calls_and_oracle_exact(small_graph):
    """Acceptance: the session mines the "full" feature group with
    STRICTLY fewer kernel invocations than the per-pattern
    CompiledPattern loop, with oracle-identical counts."""
    patterns = feature_pattern_set("full")
    rng = np.random.default_rng(0)
    seeds = rng.choice(small_graph.n_edges, size=200, replace=False).astype(np.int32)

    session = MiningSession(small_graph, window=4096).register(*patterns)
    res = session.mine(seeds=seeds)

    loop_calls = 0
    for j, name in enumerate(patterns):
        cp = CompiledPattern(build_pattern(name, 4096), small_graph)
        np.testing.assert_array_equal(res.counts[:, j], cp.mine(seeds))
        loop_calls += cp.stats["kernel_calls"]
    assert res.stats["kernel_calls"] < loop_calls

    # the seed-local windowed-degree family went through the fused kernel
    assert set(res.fused) == {"fan_in", "fan_out", "deg_in", "deg_out",
                              "cycle2", "stack"}
    for j, name in enumerate(patterns):
        ref = GFPReference(build_pattern(name, 4096), small_graph).mine(seeds)
        np.testing.assert_array_equal(res.counts[:, j], ref)


def test_full_deep_session_vs_loop_vs_oracle(dense_graph):
    """Session exactness on the full_deep group (chained-frontier depth-3+
    patterns included) against both the loop and the enumerator."""
    patterns = feature_pattern_set("full_deep")
    session = MiningSession(dense_graph, window=W).register(*patterns)
    res = session.mine()
    orc = session.mine(backend="oracle")
    np.testing.assert_array_equal(res.counts, orc.counts)
    assert res.columns == orc.columns == tuple(patterns)
    for j, name in enumerate(patterns):
        cp = CompiledPattern(build_pattern(name, W), dense_graph)
        np.testing.assert_array_equal(res.counts[:, j], cp.mine())


def test_backends_agree(dense_graph):
    names = ["fan_in", "cycle3", "scatter_gather", "stack"]
    session = MiningSession(dense_graph, window=W).register(*names)
    base = session.mine()
    for backend in ("oracle", "streaming", "partitioned"):
        got = session.mine(backend=backend, n_parts=3)
        np.testing.assert_array_equal(got.counts, base.counts, err_msg=backend)
    part = session.mine(backend="partitioned", n_parts=3)
    assert part.partition_plan is not None
    assert len(part.per_part_seconds) == 3
    with pytest.raises(ValueError, match="unknown backend"):
        session.mine(backend="nope")


def test_seed_subset_and_result_accessors(dense_graph):
    session = MiningSession(dense_graph, window=W).register("fan_in", "cycle3")
    seeds = np.array([3, 0, 17, 5], dtype=np.int32)
    res = session.mine(seeds=seeds)
    assert res.counts.shape == (4, 2) and res.n_seeds == 4
    full = session.mine()
    np.testing.assert_array_equal(res.column("cycle3"), full.column("cycle3")[seeds])
    feats = res.as_features()
    assert feats.dtype == np.float32 and feats.shape == (4, 2)
    assert res.totals()["fan_in"] == int(res.column("fan_in").sum())
    assert "cycle3" in res.seconds and "fan_in" in res.seconds


def test_canonical_dedup_shares_one_plan(dense_graph):
    """Two structurally identical patterns (different authoring names)
    canonicalize to one key, compile once, and mine once."""
    clone = (
        pattern("cycle3_alias")
        .for_all("hop", seed.dst.out, skip=[seed.dst, seed.src], after_seed=W)
        .count_edges("back", "hop", seed.src, after_stage="hop", until_seed=W)
        .emit("back")
        .build()
    )
    assert canonical_key(clone) == canonical_key(build_pattern("cycle3", W))
    session = MiningSession(dense_graph, window=W).register("cycle3", clone)
    session.compile()
    assert len(session._compiled) == 1  # one shared compiled plan
    res = session.mine()
    np.testing.assert_array_equal(res.column("cycle3"), res.column("cycle3_alias"))
    # a second mine with only the alias reuses the same plan (no growth)
    session.mine(["cycle3_alias"])
    assert len(session._compiled) == 1


def test_register_name_conflict_rejected(dense_graph):
    session = MiningSession(dense_graph, window=W).register("cycle3")
    session.register("cycle3")  # identical re-registration is a no-op
    other = (
        pattern("cycle3").count_window("cnt", seed.dst.in_, around_seed=W, emit=True)
    )
    with pytest.raises(ValueError, match="different structure"):
        session.register(other)


def test_mine_accepts_builders_and_specs(dense_graph):
    rt3 = (
        pattern("roundtrip3")
        .for_all("w", seed.dst.out, after_seed=W, skip=[seed.src, seed.dst])
        .count_edges("close", "w", seed.src, after_stage="w")
        .emit("close")
    )
    session = MiningSession(dense_graph, window=W)
    res = session.mine([rt3, "fan_in"])
    ref = GFPReference(rt3.build(), dense_graph).mine()
    np.testing.assert_array_equal(res.column("roundtrip3"), ref)


def test_vals_cache_shared_across_patterns(dense_graph):
    """The session-level host requirement cache is one dict reused by all
    compiled plans (windowed-degree arrays computed once per graph)."""
    session = MiningSession(dense_graph, window=W).register(
        "cycle3", "cycle4", "peel_chain"
    )
    session.compile()
    caches = [id(cp._vals_cache) for cp in session._compiled.values()]
    assert len(set(caches)) == 1 and caches[0] == id(session._vals_cache)
    session.mine()
    assert len(session._vals_cache) > 0


def test_graphless_session_streams_but_cannot_mine():
    session = MiningSession(window=W).register("fan_in", "cycle3")
    with pytest.raises(ValueError, match="no graph"):
        session.mine()
    sm = session.streaming()
    assert sm.pattern_names == ("fan_in", "cycle3")
    rng = np.random.default_rng(5)
    g = random_temporal_graph(rng, n_nodes=12, n_edges=60, t_max=200)
    sm.ingest(g.src, g.dst, g.t)
    want = CompiledPattern(build_pattern("cycle3", W), sm.graph).mine()
    np.testing.assert_array_equal(sm.counts["cycle3"], want)


def test_deprecation_shims_warn_and_match(dense_graph):
    """Old repro.core.features entry points warn but return identical
    results to the session-backed repro.api successors."""
    from repro.core.features import featurize as old_featurize
    from repro.core.features import mine_features as old_mine_features

    names = ["fan_in", "cycle3"]
    with pytest.warns(DeprecationWarning, match="mine_features is deprecated"):
        old = old_mine_features(dense_graph, W, names)
    new = mine_features(dense_graph, W, names)
    np.testing.assert_array_equal(old, new)
    for j, name in enumerate(names):
        ref = GFPReference(build_pattern(name, W), dense_graph).mine()
        np.testing.assert_array_equal(old[:, j].astype(np.int64), ref)

    with pytest.warns(DeprecationWarning, match="featurize is deprecated"):
        old_x, old_cols = old_featurize(dense_graph, W, names)
    new_x, new_cols = featurize(dense_graph, W, names)
    assert old_cols == new_cols == ("src", "dst", "amount", "fan_in", "cycle3")
    np.testing.assert_array_equal(old_x, new_x)


def test_featurize_group_name(dense_graph):
    x, cols = featurize(dense_graph, W, "fan")
    assert cols == ("src", "dst", "amount", "fan_in", "fan_out")
    assert x.shape == (dense_graph.n_edges, 5)


def test_subset_mine_charges_only_requested_units(dense_graph):
    """Mining one fused pattern must not compute (or get charged for)
    the other registered seed-local patterns' count units."""
    session = MiningSession(dense_graph, window=W).register(
        "fan_in", "fan_out", "deg_in", "deg_out", "cycle2", "stack"
    )
    one = session.mine(["fan_in"])  # fan_in needs exactly 1 count unit
    all_ = session.mine()  # the six patterns span 7 deduped units
    assert one.stats["padded_elements"] * 7 == all_.stats["padded_elements"]
    np.testing.assert_array_equal(one.column("fan_in"), all_.column("fan_in"))


def test_single_host_sync_per_backend_invocation(dense_graph):
    """The async executor regime, locked in: a full-portfolio mine blocks
    on the device exactly once per backend invocation — once for the
    fused seed-local pass and once per unique compiled plan — never once
    per kernel call, chunk, or sweep step."""
    patterns = feature_pattern_set("full_deep")
    session = MiningSession(dense_graph, window=W).register(*patterns)
    res = session.mine()
    n_invocations = len(session._compiled) + (1 if res.fused else 0)
    assert res.stats["host_syncs"] == n_invocations
    assert res.stats["kernel_calls"] > n_invocations  # syncs ≪ launches
    # repeated mines replay cached bucket schedules (no numpy regrouping)
    res2 = session.mine()
    assert res2.stats["host_syncs"] == n_invocations
    assert res2.stats["schedule_hits"] == len(session._compiled)
    np.testing.assert_array_equal(res.counts, res2.counts)


def test_session_kernel_backend_pallas(dense_graph):
    """kernel_backend="pallas" routes pw compare cubes through the Pallas
    intersect op (interpret mode on CPU) with identical counts."""
    names = ["cycle3", "cycle4", "scatter_gather", "peel_chain"]
    base = MiningSession(dense_graph, window=W).register(*names).mine()
    got = (
        MiningSession(dense_graph, window=W, kernel_backend="pallas")
        .register(*names)
        .mine()
    )
    np.testing.assert_array_equal(got.counts, base.counts)
    with pytest.raises(ValueError, match="kernel backend"):
        MiningSession(dense_graph, window=W, kernel_backend="cuda").register(
            "cycle3"
        ).compile()


def test_plan_text_shows_fusion_and_sharing(small_graph):
    session = MiningSession(small_graph, window=4096).register(
        *feature_pattern_set("full")
    )
    txt = session.plan_text()
    assert "fused seed-local kernel" in txt
    assert "fan_in" in txt and "compiled cycle3" in txt
