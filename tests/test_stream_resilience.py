"""Fault tolerance of the streaming detection stack
(`repro.stream.resilience` + `repro.stream.chaos`):

* transactional ticks — a fault at EVERY stage (ingest/mine/score/
  witness) rolls the store + counts + tick counters back bit-exactly;
* WAL + checkpoint recovery — kill + restore + WAL replay yields counts
  bit-identical to the uninterrupted run, eviction and out-of-order
  feeds included; kill-mid-tick is exercised in a real subprocess
  (chaos ``kill=True`` → ``os._exit(9)``) and kill-mid-checkpoint by an
  aborted (uncommitted) step dir;
* input quarantine — poisoned batches (NaN amounts, negative/overflow
  timestamps, unknown dtypes, empty-after-quarantine) through
  ``DetectionService.submit`` AND ``TriageServer.submit``, store
  bit-exact vs batch recompute afterwards;
* degradation ladder — transient-failure retry with backoff ascends
  witnesses_off → single_device → count_only; deadline budget sheds and
  recovers, every step on the tick report;
* serving surface — TriageServer survives failed ticks (structured
  errors), exposes health/readiness, and dedups audit alerts across
  ticks on (seed, patterns, evidence hash).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.compiler import CompiledPattern
from repro.core.patterns import build_pattern
from repro.graph.csr import build_temporal_graph
from repro.launch.serve import SubmitError, TriageServer
from repro.stream import (
    DEGRADATION_LADDER,
    BatchValidator,
    DetectionService,
    FaultInjector,
    InjectedFault,
    ResilienceConfig,
    ResilientDetectionService,
    TransientFault,
    WriteAheadLog,
    make_poisoned_batch,
    store_states_equal,
)

W = 64
PORTFOLIO = ["fan_in", "cycle3"]
THRESH = {"fan_in": 2, "cycle3": 1}


def _stream(rng, n_nodes=120, n_edges=600, t_span=6000):
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    fix = src == dst
    dst[fix] = (dst[fix] + 1) % n_nodes
    t = np.sort(rng.integers(0, t_span // 4, n_edges)).astype(np.int64) * 4
    t = np.maximum(0, t + rng.integers(-8, 9, n_edges))  # OOO + dups
    amt = rng.uniform(1.0, 500.0, n_edges).astype(np.float32)
    return src, dst, t, amt


def _batches(rng, n_batches=10, **kw):
    src, dst, t, amt = _stream(rng, **kw)
    return [
        (src[ch], dst[ch], t[ch], amt[ch])
        for ch in np.array_split(np.arange(len(src)), n_batches)
    ]


def _svc_state(svc):
    return (
        svc.store.state_dict(),
        {n: svc.pattern_counts(n).copy() for n in svc.pattern_names},
        svc.tick,
    )


def _assert_state_equal(a, b, ignore_stats=False):
    assert store_states_equal(a[0], b[0], ignore_stats=ignore_stats)
    assert set(a[1]) == set(b[1])
    for n in a[1]:
        np.testing.assert_array_equal(a[1][n], b[1][n], err_msg=n)
    assert a[2] == b[2]


# ----------------------------------------------------------------------
# transactional ticks
# ----------------------------------------------------------------------
@pytest.mark.parametrize("point", ["ingest", "mine", "score", "witness"])
def test_rollback_at_every_stage(point):
    rng = np.random.default_rng(7)
    chaos = FaultInjector()
    svc = DetectionService(
        PORTFOLIO, window=W, thresholds=THRESH, witnesses=2, chaos=chaos
    )
    feed = _batches(rng, n_batches=8)
    for b in feed[:4]:
        svc.submit(*b)
    pre = _svc_state(svc)
    chaos.arm(point, times=1)
    with pytest.raises(TransientFault):
        svc.submit(*feed[4])
    assert chaos.log == [(point, pre[2] + 1)]  # the fault really fired
    _assert_state_equal(pre, _svc_state(svc))

    # the service keeps working after the rollback, and the resumed
    # stream still matches a batch recompute over everything ingested
    chaos.disarm()
    for b in feed[4:]:
        svc.submit(*b)
    src = np.concatenate([b[0] for b in feed])
    dst = np.concatenate([b[1] for b in feed])
    t = np.concatenate([b[2] for b in feed])
    full = build_temporal_graph(src, dst, t)
    for name in svc.pattern_names:
        want = CompiledPattern(build_pattern(name, W), full).mine()
        np.testing.assert_array_equal(svc.pattern_counts(name), want)


def test_rollback_is_bit_exact_under_eviction_and_growth():
    """The hard rollback cases: the failed tick evicted edges, merged
    runs, and grew node capacity — all must unwind."""
    rng = np.random.default_rng(11)
    chaos = FaultInjector()
    svc = DetectionService(
        PORTFOLIO, window=W, thresholds=THRESH, retain="auto",
        lateness=4096, chaos=chaos, node_capacity=8,
    )
    feed = _batches(rng, n_batches=12, n_edges=700, t_span=40_000)
    for b in feed[:8]:
        svc.submit(*b)
    assert svc.store.stats["edges_evicted"] > 0
    pre = _svc_state(svc)
    # new node ids force grow_nodes inside the doomed tick
    big = feed[8]
    big = (big[0] + 500, big[1] + 700, big[2], big[3])
    chaos.arm("mine", times=1, exc=InjectedFault)
    with pytest.raises(InjectedFault):
        svc.submit(*big)
    _assert_state_equal(pre, _svc_state(svc))


# ----------------------------------------------------------------------
# durable recovery (WAL + checkpoints)
# ----------------------------------------------------------------------
def _cfg(tmp_path, **kw):
    return ResilienceConfig(
        wal_dir=str(tmp_path / "wal"),
        checkpoint_dir=str(tmp_path / "ckpt"),
        **kw,
    )


def test_recovery_bit_identical_with_eviction_and_ooo(tmp_path):
    rng = np.random.default_rng(13)
    cfg = _cfg(tmp_path, checkpoint_every=4)
    kw = dict(thresholds=THRESH, retain="auto", lateness=4096, witnesses=2)
    svc = ResilientDetectionService(PORTFOLIO, window=W, resilience=cfg, **kw)
    ref = DetectionService(PORTFOLIO, window=W, **kw)
    feed = _batches(rng, n_batches=10, n_edges=700, t_span=40_000)
    for b in feed:
        svc.submit(*b)
        ref.submit(*b)
    assert svc.store.stats["edges_evicted"] > 0
    # tick 10, cadence 4 -> checkpoint at 8 + WAL tail {9, 10}
    assert svc.wal.ticks() == [9, 10]
    live = _svc_state(svc)
    del svc  # simulate the crash: only disk state survives
    rec = ResilientDetectionService.recover(
        PORTFOLIO, window=W, resilience=cfg, **kw
    )
    _assert_state_equal(live, _svc_state(rec))
    # ...and identical to the never-faulted plain service
    for n in rec.pattern_names:
        np.testing.assert_array_equal(
            rec.pattern_counts(n), ref.pattern_counts(n)
        )
    # the recovered service keeps streaming correctly
    extra = _batches(np.random.default_rng(14), n_batches=1, n_edges=60,
                     t_span=1000)[0]
    extra = (extra[0], extra[1], extra[2] + 40_000, extra[3])
    rec.submit(*extra)
    ref.submit(*extra)
    for n in rec.pattern_names:
        np.testing.assert_array_equal(
            rec.pattern_counts(n), ref.pattern_counts(n)
        )


def test_recovery_from_wal_only_and_from_fresh_dirs(tmp_path):
    rng = np.random.default_rng(17)
    cfg = _cfg(tmp_path, checkpoint_every=100)  # never checkpoints
    svc = ResilientDetectionService(
        PORTFOLIO, window=W, resilience=cfg, thresholds=THRESH
    )
    feed = _batches(rng, n_batches=5)
    for b in feed:
        svc.submit(*b)
    live = _svc_state(svc)
    rec = ResilientDetectionService.recover(
        PORTFOLIO, window=W, resilience=cfg, thresholds=THRESH
    )
    _assert_state_equal(live, _svc_state(rec))
    # empty dirs -> a fresh service at tick 0
    cfg2 = _cfg(tmp_path / "fresh")
    rec2 = ResilientDetectionService.recover(
        PORTFOLIO, window=W, resilience=cfg2, thresholds=THRESH
    )
    assert rec2.tick == 0 and rec2.store.n_live == 0


def test_aborted_checkpoint_is_ignored(tmp_path):
    """Kill-mid-checkpoint: a step dir without COMMIT (the atomic-rename
    protocol's abort residue) must not be restored from."""
    rng = np.random.default_rng(19)
    cfg = _cfg(tmp_path, checkpoint_every=2)
    svc = ResilientDetectionService(
        PORTFOLIO, window=W, resilience=cfg, thresholds=THRESH
    )
    for b in _batches(rng, n_batches=4):
        svc.submit(*b)
    live = _svc_state(svc)
    # forge the kill-mid-write residue for a later, uncommitted step
    bogus = os.path.join(cfg.checkpoint_dir, "step_00000099")
    os.makedirs(bogus)
    with open(os.path.join(bogus, "manifest.json"), "w") as f:
        f.write("{")  # torn write
    rec = ResilientDetectionService.recover(
        PORTFOLIO, window=W, resilience=cfg, thresholds=THRESH
    )
    _assert_state_equal(live, _svc_state(rec))


def test_failed_tick_leaves_no_wal_entry(tmp_path):
    """A tick that exhausts retries must remove its WAL entry and
    dead-letter the batch, so live (rolled-back) state == recovered
    state."""
    rng = np.random.default_rng(23)
    chaos = FaultInjector()
    cfg = _cfg(tmp_path, checkpoint_every=3, max_retries=1, backoff_s=0.0)
    svc = ResilientDetectionService(
        PORTFOLIO, window=W, resilience=cfg, thresholds=THRESH, chaos=chaos
    )
    feed = _batches(rng, n_batches=6)
    for b in feed[:4]:
        svc.submit(*b)
    pre = _svc_state(svc)
    chaos.arm("mine", times=5)  # outlasts every retry
    with pytest.raises(TransientFault):
        svc.submit(*feed[4])
    chaos.disarm()
    _assert_state_equal(pre, _svc_state(svc))
    assert svc.wal.last_tick() == pre[2]  # doomed entry removed
    assert svc.totals["dead_letter_ticks"] == 1
    assert svc.dead_letters[-1]["reason"] == "tick_failed"
    rec = ResilientDetectionService.recover(
        PORTFOLIO, window=W, resilience=cfg, thresholds=THRESH
    )
    _assert_state_equal(pre, _svc_state(rec))


_KILL_SCRIPT = r"""
import sys
import numpy as np
sys.path.insert(0, {src!r})
from repro.stream import (FaultInjector, ResilienceConfig,
                          ResilientDetectionService)

rng = np.random.default_rng(29)
src = rng.integers(0, 120, 600).astype(np.int32)
dst = rng.integers(0, 120, 600).astype(np.int32)
fix = src == dst
dst[fix] = (dst[fix] + 1) % 120
t = np.sort(rng.integers(0, 1500, 600)).astype(np.int64) * 4
t = np.maximum(0, t + rng.integers(-8, 9, 600))
amt = rng.uniform(1.0, 500.0, 600).astype(np.float32)

chaos = FaultInjector()
chaos.arm("mine", tick=7, kill=True)  # SIGKILL mid-tick 7
cfg = ResilienceConfig(wal_dir={wal!r}, checkpoint_dir={ckpt!r},
                       checkpoint_every=4)
svc = ResilientDetectionService(["fan_in", "cycle3"], window=64,
                                resilience=cfg,
                                thresholds={{"fan_in": 2, "cycle3": 1}},
                                chaos=chaos)
for ch in np.array_split(np.arange(600), 10):
    svc.submit(src[ch], dst[ch], t[ch], amt[ch])
raise SystemExit("unreachable: the kill must fire first")
"""


def test_kill_mid_tick_subprocess_recovers(tmp_path):
    """The real thing: a subprocess dies via os._exit(9) halfway through
    tick 7 (after the WAL append, after counts were partially written).
    Recovery from its WAL + checkpoints must equal the uninterrupted
    run's state after tick 6 — the killed tick never half-applies."""
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    wal, ckpt = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    proc = subprocess.run(
        [sys.executable, "-c",
         _KILL_SCRIPT.format(src=src_dir, wal=wal, ckpt=ckpt)],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 9, proc.stderr  # died mid-tick, as armed
    # the doomed tick 7's WAL entry survives the kill (appended before
    # the fault) — replaying it is CORRECT: it was accepted input
    cfg = ResilienceConfig(wal_dir=wal, checkpoint_dir=ckpt)
    rec = ResilientDetectionService.recover(
        PORTFOLIO, window=W, resilience=cfg, thresholds=THRESH
    )
    assert rec.tick == 7
    # oracle: the uninterrupted run over the same prefix
    rng = np.random.default_rng(29)
    s = rng.integers(0, 120, 600).astype(np.int32)
    d = rng.integers(0, 120, 600).astype(np.int32)
    fix = s == d
    d[fix] = (d[fix] + 1) % 120
    t = np.sort(rng.integers(0, 1500, 600)).astype(np.int64) * 4
    t = np.maximum(0, t + rng.integers(-8, 9, 600))
    amt = rng.uniform(1.0, 500.0, 600).astype(np.float32)
    ref = DetectionService(PORTFOLIO, window=W, thresholds=THRESH)
    for ch in np.array_split(np.arange(600), 10)[:7]:
        ref.submit(s[ch], d[ch], t[ch], amt[ch])
    _assert_state_equal(_svc_state(ref), _svc_state(rec), ignore_stats=True)


# ----------------------------------------------------------------------
# input quarantine
# ----------------------------------------------------------------------
def test_poisoned_batch_quarantined_store_stays_exact():
    rng = np.random.default_rng(31)
    svc = ResilientDetectionService(PORTFOLIO, window=W, thresholds=THRESH)
    clean = _batches(rng, n_batches=3)
    for b in clean:
        svc.submit(*b)
    s, d, t, a, bad = make_poisoned_batch(np.random.default_rng(1), t_base=6000)
    rep = svc.submit(s, d, t, a).report
    assert rep.quarantined == int(bad.sum())
    assert rep.n_new == int((~bad).sum())
    assert len(svc.dead_letters) == int(bad.sum())
    reasons = {r["reason"] for r in svc.dead_letters}
    assert "nan_amount" in reasons and "negative_timestamp" in reasons
    # store == batch recompute over exactly the clean rows
    srcs = np.concatenate([b[0] for b in clean] + [s[~bad].astype(np.int32)])
    dsts = np.concatenate([b[1] for b in clean] + [d[~bad].astype(np.int32)])
    ts = np.concatenate([b[2] for b in clean] + [t[~bad].astype(np.int64)])
    full = build_temporal_graph(srcs, dsts, ts)
    for name in svc.pattern_names:
        want = CompiledPattern(build_pattern(name, W), full).mine()
        np.testing.assert_array_equal(svc.pattern_counts(name), want)


def test_unknown_dtype_rejects_whole_batch():
    svc = ResilientDetectionService(PORTFOLIO, window=W, thresholds=THRESH)
    rep = svc.submit(
        np.array(["a", "b"]), np.array([1, 2]), np.array([3, 4])
    ).report
    assert rep.rejected == 2 and rep.n_new == 0
    assert svc.store.n_live == 0
    # length mismatch is a whole-batch reject too
    rep = svc.submit(np.array([1, 2, 3]), np.array([1, 2]), np.array([3, 4])).report
    assert rep.rejected == 3 and svc.store.n_live == 0


def test_empty_after_quarantine_batch_is_a_clean_tick():
    svc = ResilientDetectionService(PORTFOLIO, window=W, thresholds=THRESH)
    batch = svc.submit(
        np.array([1.0, 2.0]), np.array([2.0, 3.0]),
        np.array([-5.0, np.nan]), np.array([1.0, 1.0]),
    )
    assert len(batch) == 0
    assert batch.report.quarantined == 2
    assert batch.report.path == "empty"
    assert svc.store.n_live == 0 and svc.tick == 1


def test_late_contract_breach_counted_not_silent():
    """Edges below the eviction cutoff: the store counts them
    (late_contract_breaches) and the TickReport surfaces them; the
    quarantine's default policy dead-letters them instead."""
    rng = np.random.default_rng(37)
    kw = dict(thresholds=THRESH, retain=256)
    base = DetectionService(PORTFOLIO, window=W, **kw)
    for b in _batches(rng, n_batches=6, t_span=40_000):
        base.submit(*b)
    assert base.store._cutoff > 0
    stale = np.array([1], np.int32), np.array([2], np.int32), np.array([0], np.int64)
    rep = base.submit(*stale).report
    assert rep.late_contract_breach == 1
    assert base.store.stats["late_contract_breaches"] == 1
    # resilient default: quarantined before the store sees it
    res = ResilientDetectionService(
        PORTFOLIO, window=W, **kw,
        resilience=ResilienceConfig(late_policy="quarantine"),
    )
    for b in _batches(np.random.default_rng(37), n_batches=6, t_span=40_000):
        res.submit(*b)
    rep = res.submit(*stale).report
    assert rep.late_contract_breach == 1 and rep.quarantined == 1
    assert res.store.stats["late_contract_breaches"] == 0
    # explicit ingest policy reproduces the base behavior
    res2 = ResilientDetectionService(
        PORTFOLIO, window=W, **kw,
        resilience=ResilienceConfig(late_policy="ingest"),
    )
    for b in _batches(np.random.default_rng(37), n_batches=6, t_span=40_000):
        res2.submit(*b)
    rep = res2.submit(*stale).report
    assert rep.late_contract_breach == 1 and rep.quarantined == 0
    assert res2.store.stats["late_contract_breaches"] == 1


def test_validator_unit():
    v = BatchValidator()
    src = np.array([1.0, -1.0, 2.5, 3.0])
    dst = np.array([2.0, 2.0, 2.0, 2.0])
    t = np.array([10.0, 10.0, 10.0, 1e19])
    s, d, t2, a, records, counts = v.validate(src, dst, t, None, cutoff=0)
    assert counts["quarantined"] == 3 and len(s) == 1
    assert {r["reason"] for r in records} == {
        "negative_src", "non_integer_src", "timestamp_overflow"
    }
    assert s.dtype == np.int32 and t2.dtype == np.int64 and a is None


# ----------------------------------------------------------------------
# degradation ladder + retry
# ----------------------------------------------------------------------
def test_transient_retry_ascends_ladder():
    rng = np.random.default_rng(41)
    chaos = FaultInjector()
    svc = ResilientDetectionService(
        PORTFOLIO, window=W, thresholds=THRESH, witnesses=2, chaos=chaos,
        resilience=ResilienceConfig(max_retries=2, backoff_s=0.0),
    )
    feed = _batches(rng, n_batches=4)
    for b in feed[:2]:
        svc.submit(*b)
    chaos.arm("mine", times=2)  # fail twice, succeed on the third try
    batch = svc.submit(*feed[2])
    assert batch.report.retries == 2
    assert batch.report.degraded == DEGRADATION_LADDER[:2]
    assert len(chaos.log) == 2
    # the successful (degraded) tick's counts are still exact
    src = np.concatenate([b[0] for b in feed[:3]])
    dst = np.concatenate([b[1] for b in feed[:3]])
    t = np.concatenate([b[2] for b in feed[:3]])
    full = build_temporal_graph(src, dst, t)
    for name in svc.pattern_names:
        want = CompiledPattern(build_pattern(name, W), full).mine()
        np.testing.assert_array_equal(svc.pattern_counts(name), want)
    # the shared kernel caches and witness config came back
    assert svc.witnesses == 2 and not svc._count_only
    nxt = svc.submit(*feed[3])
    assert nxt.report.retries == 0 and nxt.report.degraded == ()


def test_deadline_budget_sheds_and_recovers():
    rng = np.random.default_rng(43)
    svc = ResilientDetectionService(
        PORTFOLIO, window=W, thresholds=THRESH, witnesses=2,
        resilience=ResilienceConfig(
            deadline_ms=0.0, recover_after_ticks=2  # every tick breaches
        ),
    )
    feed = _batches(rng, n_batches=6)
    svc.submit(*feed[0])
    assert svc._level == 1  # breach raised the standing level
    rep = svc.submit(*feed[1]).report
    assert "witnesses_off" in rep.degraded
    assert svc._level == 2  # second breach climbed another rung
    # widen the budget: each recover_after_ticks clean ticks decay a rung
    svc.resilience.deadline_ms = 60_000.0
    for b in feed[2:6]:
        svc.submit(*b)
    assert svc._level == 0


def test_count_only_rung_still_counts_exactly():
    rng = np.random.default_rng(47)
    svc = ResilientDetectionService(
        PORTFOLIO, window=W, thresholds=THRESH, witnesses=2
    )
    svc._level = 3  # pin the harshest rung
    feed = _batches(rng, n_batches=3)
    for b in feed:
        batch = svc.submit(*b)
        assert len(batch) == 0  # no alerts in count_only
        assert batch.report.degraded == DEGRADATION_LADDER
    src = np.concatenate([b[0] for b in feed])
    dst = np.concatenate([b[1] for b in feed])
    t = np.concatenate([b[2] for b in feed])
    full = build_temporal_graph(src, dst, t)
    for name in svc.pattern_names:
        want = CompiledPattern(build_pattern(name, W), full).mine()
        np.testing.assert_array_equal(svc.pattern_counts(name), want)


# ----------------------------------------------------------------------
# serving surface (TriageServer)
# ----------------------------------------------------------------------
def test_triage_server_survives_failed_ticks_and_reports_health():
    rng = np.random.default_rng(53)
    chaos = FaultInjector()
    svc = ResilientDetectionService(
        PORTFOLIO, window=W, thresholds=THRESH, chaos=chaos,
        resilience=ResilienceConfig(max_retries=0),
    )
    server = TriageServer(svc)
    feed = _batches(rng, n_batches=4)
    server.submit(*feed[0])
    pre = _svc_state(svc)
    chaos.arm("mine", times=1, exc=InjectedFault)
    err = server.submit(*feed[1])
    assert isinstance(err, SubmitError)
    assert err.error == "InjectedFault" and err.rolled_back
    _assert_state_equal(pre, _svc_state(svc))
    # still serving
    chaos.disarm()
    out = server.submit(*feed[2])
    assert not isinstance(out, SubmitError)
    h = server.health()
    assert h["ready"] and h["errors"] == 1
    assert h["last_error"]["error"] == "InjectedFault"
    assert h["service"]["tick"] == svc.tick
    assert server.ready()
    server.close()
    assert not server.ready()


def test_triage_server_poisoned_input_containment(tmp_path):
    svc = ResilientDetectionService(PORTFOLIO, window=W, thresholds=THRESH)
    server = TriageServer(svc, audit_path=str(tmp_path / "audit.jsonl"))
    s, d, t, a, bad = make_poisoned_batch(np.random.default_rng(3))
    batch = server.submit(s, d, t, a)
    assert not isinstance(batch, SubmitError)
    assert batch.report.quarantined == int(bad.sum())
    # base (non-resilient) service: poison raises inside, server contains
    raw = DetectionService(PORTFOLIO, window=W, thresholds=THRESH)
    raw_server = TriageServer(raw)
    err = raw_server.submit(s, d, t, a)
    assert isinstance(err, SubmitError)
    assert raw.store.n_live == 0  # rolled back, not corrupted
    server.close()


def test_audit_log_dedups_repeat_alerts(tmp_path):
    """A seed re-firing with identical (patterns, evidence) must not
    re-emit its audit line; close() flushes one repeat_count summary."""
    rng = np.random.default_rng(59)
    path = tmp_path / "audit.jsonl"
    svc = DetectionService(["fan_in"], window=W, thresholds={"fan_in": 2})
    server = TriageServer(svc, audit_path=str(path))
    # a stable fan-in hub re-mined every tick: same seeds re-fire with
    # the same counts until new spokes arrive
    hub_src = np.arange(2, 8, dtype=np.int32)
    hub_dst = np.zeros(6, dtype=np.int32)
    hub_t = np.full(6, 100, dtype=np.int64)
    server.submit(hub_src, hub_dst, hub_t)
    n_first = server.n_alerts
    assert n_first > 0
    assert server.n_suppressed == 0
    # re-touch the hub so the same seeds re-fire: (eid, patterns,
    # evidence) is unchanged -> suppressed, not re-emitted
    server.submit(
        np.array([8], np.int32), np.array([0], np.int32),
        np.array([101], np.int64),
    )
    server.submit(
        np.array([9], np.int32), np.array([0], np.int32),
        np.array([102], np.int64),
    )
    assert server.n_suppressed > 0
    server.close()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    alerts = [l for l in lines if "eid" in l and not l.get("dedup")]
    dedups = [l for l in lines if l.get("dedup")]
    # one audit line per distinct alert key, ever
    keys = {(a["eid"], tuple(a["patterns"])) for a in alerts}
    assert len(alerts) == len(keys)
    assert server.n_alerts > len(alerts)  # some alerts were suppressed
    assert server.n_suppressed == sum(d["repeat_count"] - 1 for d in dedups)
    assert all(d["repeat_count"] >= 2 for d in dedups)


# ----------------------------------------------------------------------
# WAL unit behavior
# ----------------------------------------------------------------------
def test_wal_round_trip_and_prune(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    rng = np.random.default_rng(61)
    for k in range(1, 5):
        wal.append(k, rng.integers(0, 9, 4), rng.integers(0, 9, 4),
                   np.arange(4) + k, None if k == 2 else rng.uniform(size=4))
    assert wal.ticks() == [1, 2, 3, 4] and wal.last_tick() == 4
    got = dict(wal.entries(after=2))
    assert sorted(got) == [3, 4]
    assert got[3][3] is not None and got[3][0].dtype == np.int32
    assert next(wal.entries(after=1))[1][3] is None  # tick 2 had no amounts
    wal.prune_through(3)
    assert wal.ticks() == [4]
    wal.remove(4)
    assert wal.last_tick() is None
