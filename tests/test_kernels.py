"""Pallas kernel validation: interpret-mode vs pure-jnp oracle, with
shape/dtype sweeps and hypothesis randomization."""
import numpy as np
import jax.numpy as jnp
import pytest
from tests.hypothesis_compat import given, settings, st

from repro.kernels import hist_update, intersect_count, window_degree
from repro.kernels.hist_update.ref import hist_update_ref
from repro.kernels.intersect_count.ref import intersect_count_ref
from repro.kernels.window_degree.kernel import PAD_T
from repro.kernels.window_degree.ref import window_degree_ref


def _intersect_case(b, da, db, ordered):
    rng = np.random.default_rng(b * 100 + da + db)
    a_ids = rng.integers(-1, 8, (b, da)).astype(np.int32)
    b_ids = rng.integers(-1, 8, (b, db)).astype(np.int32)
    a_t = rng.integers(0, 64, (b, da)).astype(np.int32)
    b_t = rng.integers(0, 64, (b, db)).astype(np.int32)
    a_lo = rng.integers(-4, 32, b).astype(np.int32)
    a_hi = a_lo + rng.integers(0, 64, b).astype(np.int32)
    b_lo = rng.integers(-4, 32, b).astype(np.int32)
    b_hi = b_lo + rng.integers(0, 64, b).astype(np.int32)
    args = tuple(map(jnp.asarray, (a_ids, a_t, b_ids, b_t, a_lo, a_hi, b_lo, b_hi)))
    got = intersect_count(*args, ordered=ordered)
    ref = intersect_count_ref(*args, ordered=ordered)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("b,da,db", [(1, 1, 1), (5, 8, 3), (16, 32, 32), (33, 7, 65)])
@pytest.mark.parametrize("ordered", [False, True])
def test_intersect_count_shapes(b, da, db, ordered):
    _intersect_case(b, da, db, ordered)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_intersect_count_hypothesis(seed):
    rng = np.random.default_rng(seed)
    _intersect_case(
        int(rng.integers(1, 20)),
        int(rng.integers(1, 40)),
        int(rng.integers(1, 40)),
        bool(rng.integers(0, 2)),
    )


@pytest.mark.parametrize("ordered", [False, True])
def test_intersect_count_ragged_ordered_windows(ordered):
    """Ref-vs-Pallas parity on the executor's hard cases: ragged rows
    (fully padded sides, uneven -1 tails), duplicate ids (multi-edges),
    inverted/degenerate windows, and ordered-mode ties at equal times."""
    a_ids = np.array(
        [
            [3, 3, 3, -1],   # duplicate ids vs duplicate b ids
            [-1, -1, -1, -1],  # fully padded frontier side
            [0, 1, 2, 3],
            [5, 5, -1, -1],
            [7, 7, 7, 7],
        ],
        np.int32,
    )
    a_t = np.array(
        [
            [10, 20, 30, 99],
            [0, 0, 0, 0],
            [5, 6, 7, 8],
            [50, 60, 0, 0],
            [10, 10, 10, 10],  # ordered ties: b_t == a_t must NOT count
        ],
        np.int32,
    )
    b_ids = np.array(
        [
            [3, 3, -1],
            [1, 2, 3],
            [-1, -1, -1],  # fully padded fixed side
            [5, 5, 5],
            [7, 7, 7],
        ],
        np.int32,
    )
    b_t = np.array(
        [
            [15, 25, 0],
            [1, 2, 3],
            [0, 0, 0],
            [55, 65, 75],
            [10, 11, 9],
        ],
        np.int32,
    )
    a_lo = np.array([0, 0, 4, 40, 0], np.int32)
    a_hi = np.array([25, 10, 9, 70, 99], np.int32)
    b_lo = np.array([0, 0, 0, 60, 0], np.int32)
    b_hi = np.array([30, 10, 9, 50, 99], np.int32)  # row 3: inverted window
    args = tuple(
        map(jnp.asarray, (a_ids, a_t, b_ids, b_t, a_lo, a_hi, b_lo, b_hi))
    )
    got = intersect_count(*args, ordered=ordered)
    ref = intersect_count_ref(*args, ordered=ordered)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # spot-check the semantics the compiled pw lowering depends on
    if not ordered:
        assert int(np.asarray(got)[0]) == 4  # 2 in-window a x 2 in-window b
        assert int(np.asarray(got)[3]) == 0  # inverted window kills row 3
    else:
        assert int(np.asarray(got)[4]) == 4  # only b_t=11 > every a_t=10


@pytest.mark.parametrize("b,d", [(1, 1), (7, 16), (64, 128), (100, 33)])
def test_window_degree_shapes(b, d):
    rng = np.random.default_rng(b + d)
    t = rng.integers(0, 128, (b, d)).astype(np.int32)
    t[rng.random((b, d)) < 0.25] = PAD_T
    lo = rng.integers(0, 64, b).astype(np.int32)
    hi = lo + rng.integers(0, 64, b).astype(np.int32)
    got = window_degree(jnp.asarray(t), jnp.asarray(lo), jnp.asarray(hi))
    ref = window_degree_ref(jnp.asarray(t), jnp.asarray(lo), jnp.asarray(hi))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("n,s", [(16, 8), (1000, 97), (4096, 512), (513, 2048)])
def test_hist_update_shapes(n, s):
    rng = np.random.default_rng(n + s)
    keys = rng.integers(-2, s + 2, n).astype(np.int32)
    gh = rng.normal(size=(n, 2)).astype(np.float32)
    got = hist_update(jnp.asarray(keys), jnp.asarray(gh), s)
    ref = hist_update_ref(jnp.asarray(keys), jnp.asarray(gh), s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_hist_update_f32_accumulation():
    # many duplicate keys: accumulation order differs (matmul), tolerance
    rng = np.random.default_rng(0)
    keys = np.zeros(2048, dtype=np.int32)
    gh = rng.normal(size=(2048, 2)).astype(np.float32)
    got = hist_update(jnp.asarray(keys), jnp.asarray(gh), 4)
    np.testing.assert_allclose(
        np.asarray(got)[0], gh.sum(axis=0), rtol=1e-4, atol=1e-4
    )
    assert np.all(np.asarray(got)[1:] == 0)
