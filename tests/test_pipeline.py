"""End-to-end pipeline behaviour (paper Table 2 trend) + data generators."""
import numpy as np
import pytest

from repro.data.loader import temporal_split
from repro.data.synth_aml import DATASET_PRESETS, generate_aml_dataset
from repro.data.trovares import generate_trovares_graph
from repro.ml.gbdt import GBDTParams
from repro.ml.pipeline import run_aml_pipeline


def test_dataset_presets_deterministic():
    a = generate_aml_dataset("HI-Small", seed=5, scale=0.2)
    b = generate_aml_dataset("HI-Small", seed=5, scale=0.2)
    assert a.graph.n_edges == b.graph.n_edges
    np.testing.assert_array_equal(a.graph.src, b.graph.src)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_dataset_rates():
    for name in ("LI-Small", "HI-Small"):
        ds = generate_aml_dataset(name, seed=0, scale=0.4)
        assert 0 < ds.illicit_rate < 0.05
    hi = generate_aml_dataset("HI-Small", seed=0, scale=0.4)
    li = generate_aml_dataset("LI-Small", seed=0, scale=0.4)
    assert hi.illicit_rate > 2 * li.illicit_rate  # HI means high-illicit


def test_temporal_split():
    ds = generate_aml_dataset("LI-Small", seed=0, scale=0.2)
    tr, te = temporal_split(ds)
    assert len(tr) + len(te) == ds.graph.n_edges
    assert ds.graph.t[tr].max() <= ds.graph.t[te].min()
    assert 0.75 < len(tr) / ds.graph.n_edges < 0.85


def test_trovares_sizes():
    g = generate_trovares_graph(5000, seed=0)
    assert g.n_edges == 5000


@pytest.mark.slow
def test_mined_features_beat_baseline():
    """Paper Table 2: graph features lift F1 over the XGB-only baseline."""
    ds = generate_aml_dataset("HI-Small", seed=0, scale=0.5)
    base = run_aml_pipeline(ds, "xgb_only", params=GBDTParams(n_trees=30))
    full = run_aml_pipeline(ds, "full", params=GBDTParams(n_trees=30))
    assert full.f1 > base.f1, (base.f1, full.f1)
    assert full.f1 > 0.3, full.f1


def test_hlo_collective_parser():
    from repro.launch.hlo_analysis import collective_bytes, roofline

    text = """
  %ag = bf16[4,1024]{1,0} all-gather(%p0), replica_groups=...
  %ar.1 = f32[256]{0} all-reduce(%x), to_apply=%sum
  %ars = (f32[128]{0}, f32[128]{0}) all-reduce-start(%y, %z)
  %ard = f32[128]{0} all-reduce-done(%ars)
  %cp = u8[64]{0} collective-permute(%w), source_target_pairs=...
  %notacoll = f32[9]{0} add(%a, %b)
"""
    got = collective_bytes(text)
    assert got["all-gather"] == 4 * 1024 * 2
    assert got["all-reduce"] == 256 * 4 + 2 * 128 * 4
    assert got["collective-permute"] == 64
    assert got["total"] == got["all-gather"] + got["all-reduce"] + 64
    r = roofline({"flops": 197e12, "bytes accessed": 819e9}, got, 256, model_flops=197e12 * 256)
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 1.0) < 1e-9
    assert r["dominant"] in ("compute_s", "memory_s")
    assert abs(r["useful_flops_ratio"] - 1.0) < 1e-9
