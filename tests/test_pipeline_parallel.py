"""GPipe pipeline parallelism: piped forward == sequential forward.
Runs in a subprocess with 4 host devices (pipe axis)."""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed.pipeline import pipeline_forward

mesh = jax.make_mesh((4,), ("pipe",))
S, M, MB, D = 4, 8, 2, 16
rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32) * 0.3)
x = jnp.asarray(rng.normal(size=(M, MB, D)).astype(np.float32))

def stage_fn(wi, h):
    return jnp.tanh(h @ wi)

got = pipeline_forward(mesh, stage_fn, w, x)

ref = x
for s in range(S):
    ref = jnp.tanh(ref @ w[s])

err = float(jnp.max(jnp.abs(got - ref)))
print("RESULT " + json.dumps({"err": err}))
"""


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")][-1]
    assert json.loads(line[len("RESULT "):])["err"] < 1e-5
