"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real (single) device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import numpy as np
import pytest

from repro.data.synth_aml import generate_aml_dataset


@pytest.fixture(scope="session")
def small_ds():
    return generate_aml_dataset("HI-Small", seed=7, scale=0.25)


@pytest.fixture(scope="session")
def small_graph(small_ds):
    return small_ds.graph


def random_temporal_graph(rng, n_nodes=24, n_edges=160, t_max=512):
    from repro.graph.csr import build_temporal_graph

    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    fix = src == dst
    dst[fix] = (dst[fix] + 1) % n_nodes
    t = rng.integers(0, t_max, n_edges).astype(np.int64)
    return build_temporal_graph(src, dst, t, n_nodes=n_nodes)
