"""Render EXPERIMENTS.md tables from results/dryrun.json.

  PYTHONPATH=src python results/render_tables.py [results/dryrun.json]
"""
import json
import sys


def main(path="results/dryrun.json"):
    with open(path) as f:
        r = json.load(f)

    print("### Roofline (single-pod 16x16, per chip)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "useful flops ratio | roofline frac | temp GB/chip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for key in sorted(r):
        rec = r[key]
        if rec.get("mesh") != "16x16":
            continue
        a, s = rec["arch"], rec["shape"]
        if rec["status"] == "skipped":
            print(f"| {a} | {s} | — | — | — | skip (full attn @500k) | — | — | — |")
            continue
        if rec["status"] != "ok" or "roofline" not in rec:
            print(f"| {a} | {s} | ERROR {rec.get('error','')[:40]} | | | | | | |")
            continue
        rl = rec["roofline"]
        mem = rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
        print(
            f"| {a} | {s} | {rl['compute_s']*1e3:.1f} ms | "
            f"{rl['memory_s']*1e3:.1f} ms | {rl['collective_s']*1e3:.1f} ms | "
            f"{rl['dominant'].replace('_s','')} | "
            f"{rl.get('useful_flops_ratio', 0):.2f} | "
            f"{rl.get('roofline_fraction', 0):.3f} | {mem:.1f} |"
        )

    print("\n### Multi-pod (2x16x16) shard proof\n")
    print("| arch | shape | status | compile s | temp GB/chip |")
    print("|---|---|---|---|---|")
    for key in sorted(r):
        rec = r[key]
        if rec.get("mesh") != "2x16x16":
            continue
        mem = rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
        st = rec["status"] if rec["status"] != "skipped" else "skip"
        print(
            f"| {rec['arch']} | {rec['shape']} | {st} | "
            f"{rec.get('compile_s', '—')} | {mem:.1f} |"
        )


if __name__ == "__main__":
    main(*sys.argv[1:])
